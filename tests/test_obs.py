"""Observability-layer tests: metrics registry, span tracing, leveled log,
predicted-vs-measured ledger, and the serving telemetry wired through them.

The serving assertions are *exact-count* tests on a fully deterministic
workload (greedy decode, fixed prompts, single slot where needed): the
telemetry IS the acceptance contract of PRs 3-4 (sync reduction, bounded
per-tick prompt work, zero recomputation on full prefix hits), so the
numbers are asserted, not just their signs.
"""

import json
import threading

import jax
import numpy as np
import pytest

from repro import obs as obs_lib
from repro.configs import get_smoke_config
from repro.models import lm
from repro.obs import log
from repro.obs.check import check_metrics_doc, check_trace_doc
from repro.obs.ledger import Ledger
from repro.obs.log import fmt_or_na
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.runtime import DecodeServer, Request


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_basic():
    m = MetricsRegistry()
    c = m.counter("reqs", "requests", route="decode")
    c.inc()
    c.inc(4)
    assert c.value == 5
    # same (name, labels) -> same child; different labels -> sibling
    assert m.counter("reqs", route="decode") is c
    other = m.counter("reqs", route="prefill")
    assert other is not c and other.value == 0
    assert m.value("reqs", route="decode") == 5
    assert {ch.labels["route"] for ch in m.children("reqs")} == \
        {"decode", "prefill"}
    g = m.gauge("depth")
    g.set(3)
    g.set_max(1)    # lower: no change
    g.set_max(7)
    assert g.value == 7
    g.add(-2)
    assert g.value == 5


def test_kind_collision_rejected():
    m = MetricsRegistry()
    m.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        m.gauge("x")


def test_histogram_percentiles_exact():
    m = MetricsRegistry()
    h = m.histogram("lat_ms")
    for v in range(1, 101):           # 1..100, under the reservoir size
        h.observe(v)
    s = h.summary()
    assert s["count"] == 100 and s["min"] == 1 and s["max"] == 100
    assert s["sum"] == pytest.approx(5050)
    # nearest-rank on the full population
    assert s["p50"] == 50 and s["p95"] == 95 and s["p99"] == 99
    assert m.histogram("empty").summary()["p50"] is None


def test_registry_reset_keeps_families():
    m = MetricsRegistry()
    c = m.counter("n")
    h = m.histogram("d")
    c.inc(3)
    h.observe(1.0)
    m.reset()
    assert c.value == 0 and h.summary()["count"] == 0
    # the SAME handles keep working after reset (hot-path handle caching)
    c.inc()
    assert m.value("n") == 1


def test_snapshot_and_prometheus():
    m = MetricsRegistry()
    m.counter("hits", "cache hits", kind="full").inc(2)
    m.gauge("depth").set(4)
    m.histogram("ms").observe(10.0)
    snap = m.snapshot()
    assert snap["counters"]["hits{kind=full}"] == 2
    assert snap["gauges"]["depth"] == 4
    assert snap["histograms"]["ms"]["count"] == 1
    text = m.to_prometheus()
    assert '# TYPE hits counter' in text
    assert 'hits{kind="full"} 2' in text
    assert "# TYPE ms summary" in text
    assert "ms_count 1" in text
    json.loads(m.to_json())           # valid JSON


def test_registry_thread_safety():
    m = MetricsRegistry()
    c = m.counter("n")
    h = m.histogram("v")

    def work():
        for i in range(1000):
            c.inc()
            h.observe(i)

    ts = [threading.Thread(target=work) for _ in range(8)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert c.value == 8000
    assert h.summary()["count"] == 8000


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_tracer_disabled_is_null():
    tr = Tracer(enabled=False)
    span = tr.span("x")
    assert span is tr.span("y")       # one shared null context manager
    with span:
        pass
    tr.instant("i")
    tr.counter("c", {"v": 1})
    tr.thread_name(0, "server")
    assert tr.events() == []


def test_tracer_spans_and_schema(tmp_path):
    tr = Tracer(enabled=True)
    tr.thread_name(0, "server")
    with tr.span("outer", cat="test", args={"k": 1}):
        with tr.span("inner", cat="test"):
            pass
    tr.instant("mark")
    by_name = {e["name"]: e for e in tr.events()}
    outer, inner = by_name["outer"], by_name["inner"]
    assert outer["ph"] == "X" and inner["ph"] == "X"
    # nesting by timestamp containment on the same track
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert outer["args"] == {"k": 1}
    assert by_name["thread_name"]["ph"] == "M"
    path = tmp_path / "trace.json"
    doc = tr.export(str(path))
    assert doc["traceEvents"] and json.load(open(path)) == doc
    assert check_trace_doc(doc) == []
    tr.reset()
    assert tr.events() == []


def test_trace_doc_schema_rejects_malformed():
    assert check_trace_doc({"nope": 1})
    assert check_trace_doc({"traceEvents": [{"ph": "X"}]})  # missing fields


# ---------------------------------------------------------------------------
# log levels (satellite: REPRO_LOG + dryrun flops=None rendering)
# ---------------------------------------------------------------------------

def test_log_levels(monkeypatch, capsys):
    monkeypatch.setenv("REPRO_LOG", "info")
    log.info("hello", n=3)
    log.debug("hidden")
    out = capsys.readouterr().out
    assert out == "hello n=3\n"
    monkeypatch.setenv("REPRO_LOG", "debug")
    log.debug("shown")
    assert "[debug] shown" in capsys.readouterr().out
    monkeypatch.setenv("REPRO_LOG", "quiet")
    log.info("silent")
    log.warning("silent too")
    got = capsys.readouterr()
    assert got.out == "" and got.err == ""


def test_fmt_or_na():
    # the dryrun crash: f"...{None:.3e}" raised; fmt_or_na renders 'n/a'
    assert fmt_or_na(None) == "n/a"
    assert fmt_or_na("n/a") == "n/a"
    assert fmt_or_na(True) == "n/a"
    assert fmt_or_na(12345.0) == "1.234e+04"
    assert fmt_or_na(7, "{:d}") == "7"


# ---------------------------------------------------------------------------
# ledger
# ---------------------------------------------------------------------------

def test_ledger_join_and_derived_columns():
    led = Ledger()
    led.predict("prog|xla|u1|c1", fsm_cycles=1000, flops=2e6, peak_bytes=None)
    led.measure("prog|xla|u1|c1", wall_s=2e-3)
    led.measure("prog|xla|u1|c1", wall_s=1e-3)     # best-of wins
    led.predict("other", fsm_cycles=5)             # predicted-only row
    rows = {r["program"]: r for r in led.report()}
    r = rows["prog|xla|u1|c1"]
    assert r["fsm_cycles"] == 1000 and r["measured_calls"] == 2
    assert r["measured_wall_us"] == pytest.approx(1000.0)
    assert "peak_bytes" not in r["predicted"]      # None dropped
    # implied clock: cycles / wall_us -> 1000 cycles in 1000us = 1 MHz
    assert r["implied_clock_mhz"] == pytest.approx(1.0)
    assert r["measured_gflops"] == pytest.approx(2e6 / 1e-3 / 1e9)
    assert rows["other"]["measured_wall_us"] is None
    table = led.format_table()
    assert "prog|xla|u1|c1" in table and "n/a" in table
    led.reset()
    assert led.format_table().startswith("(ledger empty")


def test_synthesize_populates_ledger_and_cache_counter():
    from repro.core.synthesis import NetworkSpec, synthesize

    O = obs_lib.OBS
    spec = NetworkSpec(3, 1, 4, 2, cell="gru", seq_len=5, unroll=1, c_slow=1)
    hits0 = O.metrics.value("synth_cache", result="hit")
    rep = synthesize(spec, batch=2, backend="xla")
    row = {r["program"]: r for r in O.ledger.report()}.get(
        f"{spec.name}|xla|u1|c1|b2")
    assert row is not None
    assert row["fsm_cycles"] and row["fsm_cycles"] > 0
    assert row["flops"] == rep.flops
    assert row["measured_calls"] >= 1 and row["measured_wall_us"] > 0
    assert "implied_clock_mhz" in row
    synthesize(spec, batch=2, backend="xla")       # memoized
    assert O.metrics.value("synth_cache", result="hit") == hits0 + 1


# ---------------------------------------------------------------------------
# serving telemetry: exact counts on a deterministic workload
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke_config("smollm-135m")
    return cfg, lm.init_params(cfg, jax.random.PRNGKey(0))


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(1, 40, size=n)]


def test_server_exact_telemetry_and_trace(smollm):
    """Chunked prefill + prefix cache + persistent decode, tracing on:
    every acceptance counter is asserted to its exact value."""
    cfg, params = smollm
    O = obs_lib.Observability(trace=True)
    srv = DecodeServer(cfg, params, num_slots=1, max_seq=64,
                       persistent=True, block_k=4, prefill_chunk=4,
                       prefix_cache_bytes=64 << 20, obs=O)
    prompt = _prompt(8)

    srv.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=6))
    srv.run_until_drained()
    s = srv.stats()
    # prefill: 8 prompt tokens in 2 chunks of 4; bounded by the chunk
    assert s["prefill"]["prompt_steps_computed"] == 8
    assert s["prefill"]["chunks_run"] == 2
    assert s["prefill"]["max_prompt_steps_per_tick"] == 4
    # decode: first token from prefill logits, 5 device-decoded in blocks of
    # 4 -> ceil(5/4) = 2 block dispatches = 2 host syncs
    assert s["decoded_tokens"] == 5
    assert s["decode_syncs"] == 2
    assert s["syncs_per_token"] == pytest.approx(2 / 5)
    pc = s["prefix_cache"]
    assert pc["misses"] == 1 and pc["hits"] == 0
    assert pc["insertions"] == 2          # chunk boundary @4 + prompt end @8
    assert pc["prompt_steps_saved"] == 0

    # same prompt again: full hit -> ZERO recomputed prompt steps
    srv.submit(Request(uid=1, prompt=list(prompt), max_new_tokens=6))
    srv.run_until_drained()
    s = srv.stats()
    assert s["prefill"]["prompt_steps_computed"] == 8      # unchanged
    assert s["prefix_cache"]["hits"] == 1
    assert s["prefix_cache"]["prompt_steps_saved"] == 8
    assert s["decoded_tokens"] == 10 and s["decode_syncs"] == 4
    assert s["scheduler"]["dispatched"] == 2
    lat = s["latency"]
    assert lat["ttft_ms"]["count"] == 2 and lat["ttft_ms"]["p95"] > 0
    assert lat["queue_wait_ms"]["count"] == 2
    assert lat["tpot_ms"]["count"] == 2

    # trace: schema-valid; per-request spans nest by timestamp containment
    doc = O.export_trace()
    assert check_trace_doc(doc) == []
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"decode_block", "device_sync", "prefill_chunk", "request",
            "queue_wait", "prefill", "decode", "thread_name"} <= names
    for uid in (0, 1):
        tid = uid + 1
        track = [e for e in evs if e["tid"] == tid and e["ph"] == "X"]
        parent = next(e for e in track if e["name"] == "request")
        children = [e for e in track if e["name"] != "request"]
        assert {"queue_wait", "prefill", "decode"} == \
            {e["name"] for e in children}
        for ch in children:
            assert ch["ts"] >= parent["ts"] - 1e-6
            assert ch["ts"] + ch["dur"] <= parent["ts"] + parent["dur"] + 1e-6
    # request 1 was a full cache hit: its prefill span carries no chunks
    # (all prefill_chunk spans live on the server track, and there are
    # exactly 2 — request 0's)
    assert sum(e["name"] == "prefill_chunk" for e in evs) == 2
    # metrics document cross-check: exported snapshot == stats() numbers
    mdoc = O.export_metrics(stats=s)
    assert check_metrics_doc(mdoc) == []
    assert mdoc["metrics"]["counters"]["decoded_tokens"] == s["decoded_tokens"]

    # stats(reset=True): next window starts at zero, cache entries survive
    srv.stats(reset=True)
    s = srv.stats()
    assert s["decoded_tokens"] == 0 and s["decode_syncs"] == 0
    assert s["prefix_cache"]["entries"] == 2      # checkpoints untouched


def test_partial_then_full_hit_accounting(smollm):
    """The prefix-cache audit regression test: a partial hit followed by a
    full hit of the same prompt saves start + plen in total — one decision
    per admission, never a double count.  Invariant checked against ground
    truth: computed + saved == total prompt tokens submitted."""
    cfg, params = smollm
    srv = DecodeServer(cfg, params, num_slots=1, max_seq=64,
                       prefill_chunk=4, prefix_cache_bytes=64 << 20)
    head = _prompt(4, seed=1)
    tail_a = _prompt(4, seed=2)
    tail_b = _prompt(4, seed=3)
    prompts = [head + tail_a,      # cold: miss, computes 8, inserts @4 @8
               head + tail_b,      # partial hit @4: computes 4, inserts @8
               head + tail_b]      # full hit: computes 0
    for uid, p in enumerate(prompts):
        srv.submit(Request(uid=uid, prompt=list(p), max_new_tokens=2))
        srv.run_until_drained()
    pc = srv.stats()["prefix_cache"]
    assert pc["misses"] == 1
    assert pc["partial_hits"] == 1
    assert pc["hits"] == 1
    assert pc["prompt_steps_saved"] == 4 + 8       # partial start + full plen
    computed = srv.stats()["prefill"]["prompt_steps_computed"]
    assert computed == 8 + 4 + 0
    assert computed + pc["prompt_steps_saved"] == sum(map(len, prompts))


def test_rejection_metrics(smollm):
    cfg, params = smollm
    srv = DecodeServer(cfg, params, num_slots=1, max_seq=16)
    assert not srv.submit(Request(uid=0, prompt=[], max_new_tokens=2))
    s = srv.stats()
    assert s["scheduler"]["rejected"] == {"empty_prompt": 1}
    assert srv.obs.metrics.value("requests_completed", reason="rejected") == 1
    assert srv.completed[0].finish_reason == "rejected:empty_prompt"


def test_server_tracing_disabled_by_default(smollm):
    cfg, params = smollm
    srv = DecodeServer(cfg, params, num_slots=1, max_seq=32)
    srv.submit(Request(uid=0, prompt=_prompt(3), max_new_tokens=2))
    srv.run_until_drained()
    assert srv.obs.tracer.events() == []
    assert srv.stats()["decoded_tokens"] == 1


# ---------------------------------------------------------------------------
# perf-suite regression gate (satellite: p95 gate for serve_mixed_*)
# ---------------------------------------------------------------------------

def test_perf_check_gates_ttft_p95():
    from benchmarks.perf_suite import TTFT_P95_FACTOR, check

    def payload(p95):
        return {"smoke": True, "records": [
            {"bench": "serve_mixed_chunked", "syncs_per_token": 0.5,
             "ttft_p95_ms": p95, "tick_bound_ok": True,
             "greedy_identical": True}]}

    committed = payload(100.0)
    assert check(payload(100.0 * TTFT_P95_FACTOR * 0.9), committed) == []
    bad = check(payload(100.0 * TTFT_P95_FACTOR * 1.1), committed)
    assert bad and "ttft_p95_ms" in bad[0]
    # different workload (smoke flags differ): wall-clock gate is skipped
    fresh = payload(100.0 * TTFT_P95_FACTOR * 10)
    fresh["smoke"] = False
    assert check(fresh, committed) == []
