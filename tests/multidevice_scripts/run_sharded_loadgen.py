"""Subprocess: trace-driven load generator across serving topologies.

LOADGEN_OK — one seeded trace replayed against dp=1, a dp=8 folded plan,
             and a dp=8 device-sharded plan: token digests identical across
             all three (greedy parity is topology-independent), every
             replay report passes ``repro.obs.check.check_loadgen_doc``,
             per-shard token accounting sums to the aggregate, and the
             shard-tagged ledger rows survive a metrics export round-trip.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

from repro import obs as obs_lib  # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.launch.mesh import make_local_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.obs.check import check_loadgen_doc, check_metrics_doc  # noqa: E402
from repro.runtime import (DecodeServer, ShardPlan, TraceSpec,  # noqa: E402
                           make_trace, replay)

assert jax.device_count() == 8
cfg = get_smoke_config("paper-lstm")
params = lm.init_params(cfg, jax.random.PRNGKey(0))
mesh = make_local_mesh(dp=8, tp=1)
spec = TraceSpec(num_requests=12, mean_interarrival_ticks=0.5,
                 max_new_tokens=6, vocab=cfg.vocab, seed=7)
trace = make_trace(spec)
assert make_trace(spec) == trace            # seeded determinism
kinds = {it.kind for it in trace.items}
assert "fleet" in kinds and "short" in kinds, kinds

reports = {}
for name, plan in (("dp1", None),
                   ("dp8_folded", ShardPlan(mesh, fold_data=True)),
                   ("dp8_sharded", ShardPlan(mesh))):
    obs = obs_lib.Observability()
    srv = DecodeServer(cfg, params, num_slots=8 if plan else 2, max_seq=32,
                       persistent=True, block_k=4, plan=plan, obs=obs,
                       prefix_cache_bytes=32 << 20)
    rep = replay(srv, trace)
    errs = check_loadgen_doc(rep)
    assert not errs, f"{name}: {errs}"
    assert rep["completed"] == rep["requests"] == 12
    assert sum(r["decoded_tokens"] for r in rep["per_shard"]) \
        == rep["decoded_tokens"]
    if plan is not None:
        assert len(rep["per_shard"]) == 8
        assert rep["mesh"]["layout"] == \
            ("folded" if plan.fold_data else "sharded")
        assert sum(r["dispatched"] for r in rep["per_shard"]) == 12
        # shard-tagged ledger rows round-trip through the metrics export
        doc = obs.export_metrics()
        assert not check_metrics_doc(doc), check_metrics_doc(doc)
        shards = {r["shard"] for r in doc["ledger"]
                  if r["program"].startswith("serve|loadgen|")}
        assert shards == set(range(8)), shards
    reports[name] = rep

digests = {r["tokens_digest"] for r in reports.values()}
assert len(digests) == 1, {k: v["tokens_digest"] for k, v in reports.items()}
print("LOADGEN_OK")
