"""Subprocess: explicit EP all-to-all MoE == pjit einsum MoE on 8 devices."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_smoke_config
from repro.models import moe as moe_lib
from repro.parallel.ep_moe import ep_moe_apply

mesh = Mesh(np.array(jax.devices()).reshape(8), ("model",))

cfg = dataclasses.replace(
    get_smoke_config("olmoe-1b-7b"), n_experts=16, top_k=2, capacity_factor=1.5,
)
key = jax.random.PRNGKey(0)
p = moe_lib.moe_params(key, cfg)

T_local, D = 64, cfg.d_model
x = jax.random.normal(jax.random.PRNGKey(1), (8 * T_local, D)) * 0.5

y_ep = ep_moe_apply(p, cfg, x, mesh, axis="model")

# oracle: einsum path with group == one rank's shard (same capacity policy)
y_ref, _ = moe_lib.moe_apply(p, cfg, x.reshape(8, T_local, D), group_size=T_local)
y_ref = y_ref.reshape(8 * T_local, D)

err = float(jnp.max(jnp.abs(y_ep - y_ref)))
assert err < 2e-4, f"EP vs einsum mismatch: {err}"

# schedule audit: exactly two all-to-alls in the compiled program
with mesh:
    hlo = (
        jax.jit(lambda p_, x_: ep_moe_apply(p_, cfg, x_, mesh, axis="model"))
        .lower(p, x).compile().as_text()
    )
n_a2a = len(re.findall(r" all-to-all(?:-start)?\(", hlo))
assert n_a2a == 2, f"expected exactly 2 all-to-alls, found {n_a2a}"
print("EP_MOE_OK")
