"""Subprocess: mesh-aware serving on 8 forced host devices.

PARITY_OK     — dp=8 sharded greedy decode (both drivers) is token-identical
                to the unsharded server: batch sharding is elementwise across
                slot rows, so the math never changes.
AFFINITY_OK   — a repeated prompt is placed on the shard whose prefix cache
                holds its checkpoint, even when a lower-id shard is equally
                free (shard-affine admission beats least-loaded).
QUARANTINE_OK — NaN poisoning a slot on shard 0 quarantines only that slot;
                every other shard's stream stays bit-identical.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.launch.mesh import make_local_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.runtime import DecodeServer, Request, ShardPlan  # noqa: E402

assert jax.device_count() == 8

cfg = get_smoke_config("paper-lstm")
params = lm.init_params(cfg, jax.random.PRNGKey(0))
plan = ShardPlan(make_local_mesh(dp=8, tp=1))
assert plan.dp == 8 and plan.tp == 1


def reqs(n=8, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=list(rng.integers(1, cfg.vocab,
                                             size=int(rng.integers(2, 6)))),
                    max_new_tokens=max_new)
            for i in range(n)]


def drain(plan=None, rs=None, **kw):
    srv = DecodeServer(cfg, params, num_slots=kw.pop("slots", 8),
                       max_seq=32, plan=plan, **kw)
    for r in (rs if rs is not None else reqs()):
        srv.submit(r)
    done = srv.run_until_drained()
    return {r.uid: list(r.out_tokens) for r in done}, srv


# -- parity: dp=8 vs unsharded, both decode drivers ------------------------
base, _ = drain()
shard, srv8 = drain(plan=plan)
assert base == shard, f"per-token driver diverged:\n{base}\n{shard}"
shard_p, _ = drain(plan=plan, persistent=True, block_k=4)
assert base == shard_p, "persistent driver diverged under dp=8"
mesh_stats = srv8.stats()["mesh"]
by_shard = mesh_stats["decoded_tokens_by_shard"]
assert sum(by_shard) == sum(len(v) - 1 for v in base.values())  # -1: the
# first token of each request is sampled at prefill, not by a decode tick
assert sum(1 for t in by_shard if t > 0) > 1, by_shard
print("PARITY_OK")

# -- shard affinity: the checkpoint's shard wins over lower-id shards ------
pa = [3, 1, 4, 1, 5]
pb = [2, 7, 1, 8, 2]
_, srv = drain(plan=plan, rs=[Request(uid=0, prompt=pa, max_new_tokens=3),
                              Request(uid=1, prompt=pb, max_new_tokens=3)],
               prefill_chunk=2, prefix_cache_bytes=64 << 20)
first = {tuple(r.prompt): r.shard for r in srv.completed}
assert first[tuple(pa)] == 0 and first[tuple(pb)] == 1, first
rb = Request(uid=2, prompt=list(pb), max_new_tokens=3)
srv.submit(rb)
srv.run_until_drained()
assert rb.shard == 1, f"affinity lost: placed on shard {rb.shard}"
assert rb.prefix_hit_tokens == len(pb), rb.prefix_hit_tokens
per = srv.stats()["prefix_cache"]["per_shard"]
assert per[1]["hits"] == 1 and per[0]["hits"] == 0, per
print("AFFINITY_OK")

# -- quarantine isolation: NaN on shard 0 never touches shard 1+ -----------
rs = reqs(max_new=8, seed=3)
srv = DecodeServer(cfg, params, num_slots=8, max_seq=32, plan=plan)
for r in rs:
    srv.submit(r)
srv.step()                      # all 8 live, one token decoded each
srv._poison_slot(0, "nan")      # slot 0 == shard 0 (one slot per shard)
srv.run_until_drained()
baseline, _ = drain(rs=reqs(max_new=8, seed=3))
victims = [r for r in srv.completed if r.finish_reason == "error:nonfinite"]
assert [v.uid for v in victims] == [0], victims
for r in srv.completed:
    if r.uid != 0:
        assert r.out_tokens == baseline[r.uid], f"survivor {r.uid} diverged"
# the quarantine flag itself is transient (slots are scrubbed next tick),
# so assert on the durable per-shard counters
assert int(srv.obs.metrics.value("slots_quarantined_shard", shard=0)) == 1
for s in range(1, 8):
    assert int(srv.obs.metrics.value("slots_quarantined_shard", shard=s)) == 0
assert srv.health()["mesh"]["dp"] == 8
print("QUARANTINE_OK")
