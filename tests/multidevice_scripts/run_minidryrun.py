"""Subprocess: sharded train/serve step lowers+compiles on a (2,2,2) mesh,
and the sharded loss matches the single-device loss (SPMD correctness)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import numpy as np

from repro import optim
from repro.configs import get_smoke_config
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.models.config import ShapeSpec

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

for arch in ("smollm-135m", "olmoe-1b-7b", "zamba2-1.2b"):
    cfg = dataclasses.replace(get_smoke_config(arch), remat=False)
    shape = ShapeSpec("mini_train", seq_len=16, global_batch=8, kind="train")
    lowered = steps_lib.lower_cell(cfg, shape, mesh, optim.AdamWConfig())
    compiled = lowered.compile()

    # numeric parity: sharded step loss == unsharded step loss
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = optim.init(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}

    specs = steps_lib.input_specs(cfg, shape)
    sh = steps_lib.plan_shardings(cfg, shape, mesh, specs)
    params_sh = jax.device_put(params, sh["params"])
    opt_sh = jax.tree.map(jax.device_put, opt_state,
                          optim.AdamWState(sh["opt_state"].step, sh["opt_state"].m, sh["opt_state"].v))
    batch_sh = jax.device_put(batch, sh["batch"])

    step = steps_lib.make_train_step(cfg, optim.AdamWConfig())
    with mesh:
        _, _, m_sharded = jax.jit(
            step, in_shardings=(sh["params"], sh["opt_state"], sh["batch"])
        )(params_sh, opt_sh, batch_sh)
    _, _, m_single = jax.jit(step)(params, opt_state, batch)
    np.testing.assert_allclose(float(m_sharded["loss"]), float(m_single["loss"]),
                               rtol=2e-4)
    print(f"{arch}: sharded={float(m_sharded['loss']):.6f} "
          f"single={float(m_single['loss']):.6f}")

print("MINIDRYRUN_OK")
