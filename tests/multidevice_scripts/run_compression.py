"""Subprocess: int8 error-feedback all-reduce on 8 fake devices."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.parallel.compression import (
    make_compressed_allreduce,
    reference_psum_mean,
)

N_DEV = 8
mesh = Mesh(np.array(jax.devices()).reshape(N_DEV), ("data",))
allreduce = make_compressed_allreduce(mesh, "data")

key = jax.random.PRNGKey(0)
grads = {"w": jax.random.normal(key, (N_DEV, 32, 16)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (N_DEV, 16)) * 0.1}
err = jax.tree.map(lambda g: jnp.zeros_like(g), grads)

exact = reference_psum_mean(grads)

# single step: quantization error bounded by the int8 step size
mean, err = allreduce(grads, err)
for k in grads:
    scale = float(jnp.max(jnp.abs(grads[k]))) / 127.0
    assert float(jnp.max(jnp.abs(mean[k] - exact[k]))) <= scale, k

# error feedback: across repeated steps with the same grads, the *averaged*
# compressed estimate converges to the exact mean (bias cancellation)
acc = jax.tree.map(jnp.zeros_like, exact)
steps = 30
err = jax.tree.map(lambda g: jnp.zeros_like(g), grads)
for _ in range(steps):
    mean, err = allreduce(grads, err)
    acc = jax.tree.map(lambda a, m: a + m, acc, mean)
avg = jax.tree.map(lambda a: a / steps, acc)
for k in grads:
    scale = float(jnp.max(jnp.abs(grads[k]))) / 127.0
    resid = float(jnp.max(jnp.abs(avg[k] - exact[k])))
    assert resid < 0.2 * scale, (k, resid, scale)

# wire-format check: the collective payload must be integer (compressed)
hlo = (
    jax.jit(lambda g, e: allreduce(g, e))
    .lower(grads, err)
    .compile()
    .as_text()
)
import re
ar_types = re.findall(r"(\w+)\[[\d,]*\][^=]*all-reduce", hlo)
assert any(t.startswith("s") or t.startswith("u") for t in ar_types), ar_types
print("COMPRESSION_OK")
