"""Subprocess: elastic restart — checkpoint saved under one mesh restores
onto a different topology (mesh-agnostic layout)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager

devs = np.array(jax.devices())
mesh_a = Mesh(devs.reshape(2, 4), ("data", "model"))
mesh_b = Mesh(devs.reshape(4, 2), ("data", "model"))

tree = {
    "w": jax.device_put(
        jax.random.normal(jax.random.PRNGKey(0), (16, 8)),
        NamedSharding(mesh_a, P("data", "model")),
    ),
    "b": jax.device_put(jnp.arange(8.0), NamedSharding(mesh_a, P("model"))),
}

d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
mgr.save(1, tree)

template = {
    "w": jax.ShapeDtypeStruct((16, 8), jnp.float32,
                              sharding=NamedSharding(mesh_b, P("data", "model"))),
    "b": jax.ShapeDtypeStruct((8,), jnp.float32,
                              sharding=NamedSharding(mesh_b, P("model"))),
}
restored, _ = mgr.restore(template)
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
np.testing.assert_array_equal(np.asarray(restored["b"]), np.asarray(tree["b"]))
assert restored["w"].sharding.mesh.shape["data"] == 4
print("ELASTIC_OK")
