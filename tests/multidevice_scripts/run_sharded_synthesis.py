"""Subprocess: mesh-aware synthesize()/backends on 8 forced host devices.

SYNTH_TP_OK     — xla backend with mesh (dp=4, tp=2): the [D+H, 4H] gate
                  contraction row-parallels over "model" (the compiled HLO
                  contains the gate-boundary all-reduce) and the outputs
                  match the single-device program to float tolerance (TP
                  changes the reduction order, so allclose, not bitwise).
SYNTH_PALLAS_OK — pallas backend under shard_map over "data": each shard
                  folds its local C-slow streams into its own kernel grid;
                  outputs match the unsharded fused kernel.
SYNTH_CACHE_OK  — synthesize(mesh=...) forks the memo + ledger keys (no
                  aliasing against the single-device artifact).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.codegen import build_program, pallas_backend, xla_backend  # noqa: E402
from repro.core.synthesis import NetworkSpec, synthesize  # noqa: E402
from repro.launch.mesh import make_local_mesh  # noqa: E402
from repro.obs import OBS  # noqa: E402

assert jax.device_count() == 8
mesh = make_local_mesh(dp=4, tp=2)

# lstm gate weight is [d_in + H, 4H] = [16, 32]: rows divide tp=2
spec = NetworkSpec(num_inputs=8, num_hidden_layers=2, nodes_per_layer=8,
                   num_outputs=4, cell="lstm", seq_len=6)
prog = build_program(spec)
params = prog.params
u = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (8, 6, 8)))

base = jax.jit(xla_backend.compile_program(prog))
tp = jax.jit(xla_backend.compile_program(prog, mesh=mesh))
y0, y1 = np.asarray(base(params, u)), np.asarray(tp(params, u))
np.testing.assert_allclose(y1, y0, atol=1e-5)
hlo = jax.jit(xla_backend.compile_program(prog, mesh=mesh)) \
    .lower(params, u).compile().as_text()
assert "all-reduce" in hlo, "gate TP must lower to an all-reduce"
print("SYNTH_TP_OK")

# C-slow × data shards: 4 streams over dp=4, each shard folds locally
spec_c = NetworkSpec(num_inputs=8, num_hidden_layers=1, nodes_per_layer=8,
                     num_outputs=4, cell="lstm", seq_len=6, c_slow=4)
prog_c = build_program(spec_c)
uc = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (4, 8, 6, 8)))
p0 = pallas_backend.compile_program(prog_c)
p1 = pallas_backend.compile_program(prog_c, mesh=mesh)
yc0 = np.asarray(jax.jit(p0)(prog_c.params, uc))
yc1 = np.asarray(jax.jit(p1)(prog_c.params, uc))
np.testing.assert_allclose(yc1, yc0, atol=1e-5)
print("SYNTH_PALLAS_OK")

r0 = synthesize(spec, batch=8, backend="xla", measure=False)
r1 = synthesize(spec, batch=8, backend="xla", mesh=mesh, measure=False)
assert not r0.cache_hit and not r1.cache_hit     # distinct memo keys
assert r1.backend == "xla" and r1.fallback_from is None
rows = OBS.ledger.report()
assert any(r["program"].endswith("|mesh4x2") for r in rows), \
    [r["program"] for r in rows]
print("SYNTH_CACHE_OK")
