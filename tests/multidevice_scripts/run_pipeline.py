"""Subprocess: C-slow pipeline parallelism == sequential on 4 fake devices."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.parallel.pipeline import pipeline_apply, sequential_reference

P_STAGES, C, MB, D = 4, 6, 8, 16
mesh = Mesh(np.array(jax.devices()).reshape(P_STAGES), ("stage",))

key = jax.random.PRNGKey(0)
params = {
    "w": jax.random.normal(key, (P_STAGES, D, D)) / np.sqrt(D),
    "b": 0.1 * jax.random.normal(key, (P_STAGES, D)),
}
mb = jax.random.normal(jax.random.PRNGKey(1), (C, MB, D))

stage_fn = lambda p, x: jnp.tanh(x @ p["w"] + p["b"])

out = pipeline_apply(stage_fn, params, mb, mesh)
ref = sequential_reference(stage_fn, params, mb)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

# the lowered HLO must contain the C-slow pipeline collective
with mesh:
    hlo = (
        jax.jit(lambda p, m: pipeline_apply(stage_fn, p, m, mesh))
        .lower(params, mb)
        .compile()
        .as_text()
    )
assert "collective-permute" in hlo, "pipeline must lower to collective-permute"
print("PIPELINE_OK")
