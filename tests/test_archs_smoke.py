"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and no NaNs (task deliverable f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm
from repro.models.config import applicable_shapes


def _batch(cfg, key, B=2, S=16):
    batch = {}
    if cfg.family == "encoder":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.frontend_dim))
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.family == "vlm":
        batch["memory"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.frontend_dim)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_is_well_formed(arch):
    cfg = get_config(arch)
    assert cfg.n_groups * len(cfg.layer_pattern) + len(cfg.tail_pattern) == cfg.n_layers
    assert len(applicable_shapes(cfg)) >= 2


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, key):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key)
    B, S = batch["labels"].shape

    inputs = batch.get("embeds", batch.get("tokens"))
    logits, aux = lm.forward(params, cfg, inputs, memory=batch.get("memory"), mode="train")
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.train_loss(p, cfg, batch), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).family != "encoder"])
def test_smoke_decode_matches_prefill(arch, key):
    """Teacher-forcing consistency: token-by-token decode == prefill logits.
    MoE capacity is pinned high so no tokens drop (dropping is load-dependent
    and legitimately differs between batch shapes)."""
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = lm.init_params(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    memory = None
    if cfg.family == "vlm":
        memory = jax.random.normal(key, (B, cfg.frontend_tokens, cfg.frontend_dim))

    logits_p, _ = lm.prefill(params, cfg, toks, memory=memory)
    c = lm.init_cache(cfg, B, S + 2)
    for t in range(S):
        lg, c = lm.decode_step(params, cfg, toks[:, t:t + 1], c, jnp.int32(t), memory=memory)
    np.testing.assert_allclose(
        np.asarray(lg, np.float32), np.asarray(logits_p, np.float32),
        atol=5e-4, rtol=1e-3,
    )


def test_arch_shape_matrix_counts():
    """32 runnable cells out of the nominal 40 for the ten assigned archs
    (documented skips), + 4 for paper-lstm (recurrent: all decoder shapes
    incl. long-context — the O(1) carry is sub-quadratic)."""
    total = sum(len(applicable_shapes(get_config(a))) for a in ARCH_IDS)
    assert total == 36
