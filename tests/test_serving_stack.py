"""PR 4 serving-stack tests: chunked prefill (resumable prompt scan), the
radix prefix cache, the priority/aging scheduler with admission control, and
the decode-server correctness fixes (max_new_tokens off-by-one, over-length
splice validation, cslow_scan length inference, sampled-sync accounting)."""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cslow import cslow_scan
from repro.core.state_space import StateSpaceModel
from repro.models import lm
from repro.runtime import (
    AsyncServer,
    DecodeServer,
    PrefixCache,
    Request,
    Scheduler,
    SchedulerConfig,
    splice_cache,
)


@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke_config("smollm-135m")
    return cfg, lm.init_params(cfg, jax.random.PRNGKey(0))


def _requests(vocab, n=5, max_new=6, seed=0, lo=2, hi=6):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=list(rng.integers(1, vocab, size=int(rng.integers(lo, hi)))),
                    max_new_tokens=max_new)
            for i in range(n)]


def _drain(cfg, params, reqs, **kw):
    srv = DecodeServer(cfg, params, num_slots=kw.pop("slots", 3),
                       max_seq=kw.pop("max_seq", 48), **kw)
    for r in reqs:
        srv.submit(r)
    done = srv.run_until_drained()
    return {r.uid: list(r.out_tokens) for r in done}, srv


# ---------------------------------------------------------------------------
# chunked prefill: resumable prompt scan ≡ one-shot prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-135m", "falcon-mamba-7b",
                                  "zamba2-1.2b", "gemma3-27b", "paper-lstm"])
def test_prefill_chunk_matches_prefill(arch):
    """Chaining prefill_chunk from a fresh cache reproduces one-shot prefill
    (KV, MLA, sliding-window ring, SSM h/conv, and (h,c) states alike)."""
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    T, S = 19, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, T), 1, cfg.vocab)
    lg_ref, _ = lm.prefill(params, cfg, toks)
    caches = lm.init_cache(cfg, 1, S)
    p = 0
    while p < T:
        c = min(8, T - p)
        lg, caches = lm.prefill_chunk(params, cfg, toks[:, p:p + c], caches,
                                      jnp.int32(p))
        p += c
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref), atol=1e-4)
    assert int(jnp.argmax(lg[0])) == int(jnp.argmax(lg_ref[0]))


def test_prefill_chunk_moe_greedy_parity():
    """MoE capacity-based routing drops tokens group-locally, so chunked
    logits are only approximately equal — but the greedy token matches (the
    same caveat the S=1 decode path already has)."""
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 19), 1, cfg.vocab)
    lg_ref, _ = lm.prefill(params, cfg, toks)
    caches = lm.init_cache(cfg, 1, 32)
    p = 0
    while p < 19:
        c = min(8, 19 - p)
        lg, caches = lm.prefill_chunk(params, cfg, toks[:, p:p + c], caches,
                                      jnp.int32(p))
        p += c
    assert int(jnp.argmax(lg[0])) == int(jnp.argmax(lg_ref[0]))


@pytest.mark.parametrize("arch", ["smollm-135m", "falcon-mamba-7b", "paper-lstm"])
def test_server_chunked_greedy_parity(arch):
    """Chunked-prefill serving emits token-identical greedy outputs to the
    un-chunked cache-cold path, for both decode drivers."""
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    base, _ = _drain(cfg, params, _requests(cfg.vocab))
    chunked, srv = _drain(cfg, params, _requests(cfg.vocab), prefill_chunk=2)
    assert base == chunked
    persist, _ = _drain(cfg, params, _requests(cfg.vocab), prefill_chunk=2,
                        persistent=True, block_k=4)
    assert base == persist
    assert srv.stats()["prefill"]["max_prompt_steps_per_tick"] <= 2


def test_chunked_prefill_bounds_tick_and_unblocks_decode(smollm):
    """A long prompt no longer head-of-line-blocks a live slot: with
    chunking, short requests decode (and even finish) while the long prompt
    is still prefilling, and no single tick consumes the whole prompt."""
    cfg, params = smollm
    long_prompt = list(np.random.default_rng(7).integers(1, cfg.vocab, size=24))

    def traffic():
        short = _requests(cfg.vocab, n=1, max_new=4, seed=1)[0]
        longr = Request(uid=99, prompt=list(long_prompt), max_new_tokens=2)
        return [longr, short]

    # unchunked: the long prefill lands whole in a single tick
    _, s0 = _drain(cfg, params, traffic(), slots=2, max_seq=64)
    assert s0.stats()["prefill"]["max_prompt_steps_per_tick"] >= 24
    # chunked: per-tick prompt work is bounded by the chunk
    srv = DecodeServer(cfg, params, num_slots=2, max_seq=64, prefill_chunk=4)
    longr = Request(uid=99, prompt=list(long_prompt), max_new_tokens=2)
    short = _requests(cfg.vocab, n=1, max_new=4, seed=1)[0]
    srv.submit(longr)
    srv.submit(short)
    # drive ticks manually: the short request must finish before the long
    # prompt's first token is out
    for _ in range(20):
        srv.step()
        if short.done_at is not None:
            break
    assert short.done_at is not None
    assert longr.first_token_at is None     # still prefilling
    srv.run_until_drained()
    st = srv.stats()["prefill"]
    assert st["max_prompt_steps_per_tick"] <= 4
    assert len(longr.out_tokens) == 2


def test_adaptive_prefill_bounds_contended_ticks_only(smollm):
    """Adaptive chunking: prefill arriving on an idle server drains whole
    (no fixed-chunk dispatch tax), but the chunk bound still holds on every
    tick where a live slot is decoding — and greedy outputs stay identical
    to the unchunked run."""
    cfg, params = smollm
    long_prompt = list(np.random.default_rng(7).integers(1, cfg.vocab, size=24))

    def traffic():
        longr = Request(uid=99, prompt=list(long_prompt), max_new_tokens=2)
        return [longr] + _requests(cfg.vocab, n=1, max_new=4, seed=1)

    base, _ = _drain(cfg, params, traffic(), slots=2, max_seq=64)

    # all traffic fits the slots up-front → the first tick is uncontended
    # and drains whole prompts; nothing ever prefills under contention
    ad, srv = _drain(cfg, params, traffic(), slots=2, max_seq=64,
                     prefill_chunk=4, prefill_adaptive=True)
    st = srv.stats()["prefill"]
    assert ad == base
    assert st["adaptive"] is True
    assert st["max_prompt_steps_per_tick"] >= 24      # uncontended drain
    assert st["max_prompt_steps_contended_tick"] == 0

    # long prompt submitted while a short request is decoding → its prefill
    # is contended and must honor the fixed chunk bound
    srv = DecodeServer(cfg, params, num_slots=2, max_seq=64,
                       prefill_chunk=4, prefill_adaptive=True)
    short = _requests(cfg.vocab, n=1, max_new=6, seed=1)[0]
    srv.submit(short)
    srv.step()                                         # short is now live
    srv.submit(Request(uid=99, prompt=list(long_prompt), max_new_tokens=2))
    srv.run_until_drained()
    st = srv.stats()["prefill"]
    assert 0 < st["max_prompt_steps_contended_tick"] <= 4

    # adaptive without a chunk size is a config error
    with pytest.raises(ValueError, match="prefill_adaptive"):
        DecodeServer(cfg, params, num_slots=2, max_seq=64,
                     prefill_adaptive=True)


# ---------------------------------------------------------------------------
# radix prefix cache
# ---------------------------------------------------------------------------

def test_prefix_cache_radix_structure():
    pc = PrefixCache(budget_bytes=1 << 30)
    s1 = {"h": jnp.ones((1, 4))}
    pc.insert([1, 2, 3, 4], s1, logits=jnp.ones(8), resumable=True)
    pc.insert([1, 2, 5], s1, logits=jnp.ones(8), resumable=True)
    pc.insert([1, 2], s1, logits=jnp.ones(8), resumable=True)
    # deepest-first candidates along the path
    got = [e.length for e in pc.lookup([1, 2, 3, 4, 9])]
    assert got == [4, 2]
    got = [e.length for e in pc.lookup([1, 2, 5])]
    assert got == [3, 2]
    assert pc.lookup([2, 1]) == []
    assert pc.telemetry()["entries"] == 3


def test_prefix_cache_lru_eviction():
    pc = PrefixCache(budget_bytes=1)          # everything over budget
    pc.insert([1, 2], {"h": jnp.ones((1, 4))})
    assert pc.telemetry()["evictions"] >= 1
    assert pc.telemetry()["bytes_in_use"] == 0

    big = PrefixCache(budget_bytes=2 * 16 + 8)    # each [1,4] f32 entry = 16B
    for i in range(4):
        big.insert([i, i + 1], {"h": jnp.full((1, 4), float(i))})
    t = big.telemetry()
    assert t["evictions"] == 2 and t["entries"] == 2
    assert t["bytes_in_use"] <= big.budget_bytes
    # the survivors are the most recently inserted prefixes
    assert [e.length for e in big.lookup([2, 3])] == [2]
    assert big.lookup([0, 1]) == []


def test_prefix_cache_eviction_prunes_tree_nodes():
    """Eviction must unlink the dead radix nodes, not just drop their
    entries — otherwise the tree structure (never counted against
    budget_bytes) grows one node per unique evicted prompt, forever."""
    def count_nodes(pc):
        n, stack = 0, [pc.root]
        while stack:
            node = stack.pop()
            n += 1
            stack.extend(node.children.values())
        return n

    pc = PrefixCache(budget_bytes=2 * 16 + 8)  # room for 2 [1,4] f32 entries
    for i in range(200):  # 200 disjoint prefixes through a 2-entry budget
        pc.insert([i, i + 1, i + 2], {"h": jnp.full((1, 4), float(i))})
    t = pc.telemetry()
    assert t["entries"] == 2 and t["bytes_in_use"] <= pc.budget_bytes
    assert count_nodes(pc) <= 1 + 2 * t["entries"]  # root + live paths only
    # shared-prefix splits heal too: evicting a mid node re-merges the edge
    pc2 = PrefixCache(budget_bytes=16)  # one entry fits
    pc2.insert([7, 8, 9, 10], {"h": jnp.ones((1, 4))})
    pc2.insert([7, 8], {"h": jnp.ones((1, 4))})      # splits, evicts the leaf
    pc2.insert([1, 2], {"h": jnp.ones((1, 4))})      # evicts [7,8] as well
    assert [e.length for e in pc2.lookup([1, 2])] == [2]
    assert count_nodes(pc2) == 2                     # root + the [1,2] leaf


def test_prefix_cache_full_hit_recomputes_zero_steps(smollm):
    """Second admission of an identical prompt recomputes 0 prompt steps and
    produces token-identical greedy output (hit vs miss)."""
    cfg, params = smollm
    srv = DecodeServer(cfg, params, num_slots=2, max_seq=48, prefill_chunk=4,
                       prefix_cache_bytes=64 << 20)
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]
    srv.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=5))
    srv.run_until_drained()
    cold_steps = srv.stats()["prefill"]["prompt_steps_computed"]
    assert cold_steps == len(prompt)
    srv.submit(Request(uid=1, prompt=list(prompt), max_new_tokens=5))
    done = srv.run_until_drained()
    st = srv.stats()
    assert st["prefill"]["prompt_steps_computed"] == cold_steps  # 0 more
    assert st["prefix_cache"]["hits"] == 1
    assert st["prefix_cache"]["prompt_steps_saved"] >= len(prompt)
    by = {r.uid: r.out_tokens for r in done}
    assert by[0] == by[1]
    assert done[1].prefix_hit_tokens == len(prompt)


def test_prefix_cache_partial_hit_resumes(smollm):
    """A longer prompt sharing a chunk-aligned prefix resumes mid-prompt and
    still matches the cache-cold greedy output."""
    cfg, params = smollm
    shared = [3, 1, 4, 1, 5, 9, 2, 6]                # 8 = 2 chunks of 4
    longp = shared + [8, 7, 8, 2]
    cold, _ = _drain(cfg, params,
                     [Request(uid=0, prompt=list(longp), max_new_tokens=5)])
    srv = DecodeServer(cfg, params, num_slots=2, max_seq=48, prefill_chunk=4,
                       prefix_cache_bytes=64 << 20)
    srv.submit(Request(uid=0, prompt=list(shared), max_new_tokens=3))
    srv.run_until_drained()
    base_steps = srv.stats()["prefill"]["prompt_steps_computed"]
    srv.submit(Request(uid=1, prompt=list(longp), max_new_tokens=5))
    done = srv.run_until_drained()
    st = srv.stats()
    assert st["prefix_cache"]["partial_hits"] == 1
    # only the 4 unshared tokens were recomputed
    assert st["prefill"]["prompt_steps_computed"] == base_steps + 4
    by = {r.uid: list(r.out_tokens) for r in done}
    assert by[1] == cold[0]
    assert done[1].prefix_hit_tokens == len(shared)


# ---------------------------------------------------------------------------
# scheduler: priorities, aging, admission control
# ---------------------------------------------------------------------------

def test_scheduler_priority_order():
    s = Scheduler(SchedulerConfig(aging_rate=0.0), prompt_limit=100)
    lo = Request(uid=0, prompt=[1], priority=2)
    hi = Request(uid=1, prompt=[1], priority=0)
    mid = Request(uid=2, prompt=[1], priority=1)
    for r in (lo, hi, mid):
        s.admit(r, now=0.0)
    order = [s.next_request(now=0.0).uid for _ in range(3)]
    assert order == [1, 2, 0]


def test_scheduler_fairness_aging():
    """A starved batch request overtakes fresh interactive traffic once its
    wait exceeds the class gap / aging_rate."""
    s = Scheduler(SchedulerConfig(aging_rate=1.0), prompt_limit=100)
    old_batch = Request(uid=0, prompt=[1], priority=5)
    s.admit(old_batch, now=0.0)
    fresh = Request(uid=1, prompt=[1], priority=0)
    s.admit(fresh, now=10.0)   # batch has aged 10s -> effective 5-10 = -5 < 0
    assert s.next_request(now=10.0).uid == 0
    assert s.next_request(now=10.0).uid == 1
    # fifo policy ignores classes entirely
    f = Scheduler(SchedulerConfig(policy="fifo"), prompt_limit=100)
    a = Request(uid=0, prompt=[1], priority=9)
    b = Request(uid=1, prompt=[1], priority=0)
    f.admit(a, now=0.0)
    f.admit(b, now=1.0)
    assert f.next_request(now=1.0).uid == 0


def test_scheduler_admission_control(smollm):
    cfg, params = smollm
    srv = DecodeServer(cfg, params, num_slots=2, max_seq=16,
                       scheduler=SchedulerConfig(max_queue=2))
    # queue bound
    reqs = _requests(cfg.vocab, n=4, max_new=2)
    admitted = [srv.submit(r) for r in reqs]
    assert admitted == [True, True, False, False]
    assert reqs[2].finish_reason == "rejected:queue_full"
    assert reqs[2].done_at is not None
    # empty prompt
    empty = Request(uid=9, prompt=[], max_new_tokens=2)
    assert not srv.submit(empty)
    assert empty.finish_reason == "rejected:empty_prompt"
    done = srv.run_until_drained()
    assert len(done) == 5   # 2 served + 3 rejected


def test_overlength_prompt_rejected_then_truncated(smollm):
    """Prompt length == max_seq must never reach the splice wrap path: the
    default policy rejects, the truncate policy cuts to max_seq-1."""
    cfg, params = smollm
    S = 16
    prompt = list(np.random.default_rng(0).integers(1, cfg.vocab, size=S))
    srv = DecodeServer(cfg, params, num_slots=1, max_seq=S)
    r = Request(uid=0, prompt=list(prompt), max_new_tokens=2)
    assert not srv.submit(r)
    assert r.finish_reason == "rejected:prompt_too_long"
    srv2 = DecodeServer(cfg, params, num_slots=1, max_seq=S,
                        scheduler=SchedulerConfig(overflow="truncate"))
    r2 = Request(uid=1, prompt=list(prompt), max_new_tokens=2)
    assert srv2.submit(r2)
    done = srv2.run_until_drained()
    assert done[0].truncated and len(done[0].prompt) == S - 1
    assert len(done[0].out_tokens) == 2
    # boundary: plen == max_seq - 1 admits fine
    srv3 = DecodeServer(cfg, params, num_slots=1, max_seq=S)
    r3 = Request(uid=2, prompt=list(prompt[: S - 1]), max_new_tokens=1)
    assert srv3.submit(r3)
    assert len(srv3.run_until_drained()[0].out_tokens) == 1


def test_splice_cache_overlength_full_attention_raises(smollm):
    """The p mod W wrap is for sliding-window rings only; an over-length
    full-attention source must raise, not silently corrupt the slot."""
    cfg, params = smollm
    S = 8
    toks = jax.random.randint(jax.random.PRNGKey(0), (1, 12), 1, cfg.vocab)
    _, pc = lm.prefill(params, cfg, toks)
    dst = lm.init_cache(cfg, 2, S)
    with pytest.raises(ValueError, match="reject or truncate"):
        splice_cache(dst, pc, 0, 12, S)
    with pytest.raises(ValueError, match="reject or truncate"):
        splice_cache(dst, pc, 0, 12)          # no max_seq: conservative
    # sliding-window arch: the same over-length splice wraps (ring semantics)
    gcfg = get_smoke_config("gemma3-27b")
    gparams = lm.init_params(gcfg, jax.random.PRNGKey(0))
    gtoks = jax.random.randint(jax.random.PRNGKey(0), (1, 24), 1, gcfg.vocab)
    _, gpc = lm.prefill(gparams, gcfg, gtoks)
    gdst = lm.init_cache(gcfg, 2, 32)         # window=16 < 32: rings may wrap
    out = splice_cache(gdst, gpc, 0, 24, 32)
    assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(gdst)


# ---------------------------------------------------------------------------
# max_new_tokens off-by-one + admission edges
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("persistent", [False, True])
def test_max_new_tokens_exact(smollm, persistent):
    """max_new_tokens=N emits exactly N tokens under both drivers — incl.
    N=1 (the off-by-one: prefill's sampled token IS the one token) and N=0."""
    cfg, params = smollm
    reqs = [Request(uid=n, prompt=[1, 2, 3], max_new_tokens=n)
            for n in (0, 1, 2, 5)]
    done, _ = _drain(cfg, params, reqs, persistent=persistent, block_k=4)
    assert {u: len(t) for u, t in done.items()} == {0: 0, 1: 1, 2: 2, 5: 5}


def test_first_token_parity_between_budgets(smollm):
    """The single token of a max_new=1 request equals the first token of a
    larger-budget request with the same prompt."""
    cfg, params = smollm
    one, _ = _drain(cfg, params,
                    [Request(uid=0, prompt=[5, 4, 3], max_new_tokens=1)])
    many, _ = _drain(cfg, params,
                     [Request(uid=0, prompt=[5, 4, 3], max_new_tokens=6)])
    assert one[0] == many[0][:1]


def test_async_server_priorities_and_completion(smollm):
    """asyncio front-end: concurrent generate() calls resolve with the same
    tokens as the synchronous drain; admission rejections resolve instantly."""
    cfg, params = smollm
    sync_out, _ = _drain(cfg, params, _requests(cfg.vocab, n=4, max_new=4),
                         slots=2)

    async def main():
        srv = DecodeServer(cfg, params, num_slots=2, max_seq=48,
                           prefill_chunk=2)
        aserver = AsyncServer(srv)
        reqs = _requests(cfg.vocab, n=4, max_new=4)
        bad = Request(uid=77, prompt=[], max_new_tokens=4)
        results = await asyncio.gather(*(aserver.generate(r) for r in reqs),
                                       aserver.generate(bad))
        return results

    results = asyncio.run(main())
    by = {r.uid: list(r.out_tokens) for r in results}
    assert by[77] == [] and results[-1].finish_reason == "rejected:empty_prompt"
    del by[77]
    assert by == sync_out


# ---------------------------------------------------------------------------
# telemetry fixes
# ---------------------------------------------------------------------------

def test_sampled_decode_counts_extra_syncs(smollm):
    """Legacy step() with temperature>0 pays one extra host↔device
    round-trip per live sampled slot — stats() must count them."""
    cfg, params = smollm
    greedy = [Request(uid=0, prompt=[1, 2, 3], max_new_tokens=5)]
    _, s_g = _drain(cfg, params, greedy, slots=1)
    sampled = [Request(uid=0, prompt=[1, 2, 3], max_new_tokens=5,
                       temperature=0.8)]
    _, s_s = _drain(cfg, params, sampled, slots=1)
    assert s_g.stats()["decoded_tokens"] == s_s.stats()["decoded_tokens"]
    # 4 decode ticks (first token comes from prefill): greedy = 4 syncs,
    # sampled = 4 dispatch syncs + 4 categorical round-trips
    assert s_s.stats()["decode_syncs"] == 2 * s_g.stats()["decode_syncs"]


# ---------------------------------------------------------------------------
# cslow_scan length inference fix
# ---------------------------------------------------------------------------

def test_cslow_scan_none_params_requires_length():
    model = StateSpaceModel(
        f=lambda p, x, u, k: x + u,
        g=lambda p, x, u, k: x,
    )
    x0 = jnp.zeros((2, 3))
    us = jnp.ones((2, 4, 3))
    with pytest.raises(ValueError, match="length"):
        cslow_scan(model, None, x0, us, num_streams=2)
    finals, ys = cslow_scan(model, None, x0, us, num_streams=2, length=4)
    np.testing.assert_allclose(np.asarray(finals), 4 * np.ones((2, 3)))
    assert ys.shape == (2, 4, 3)
