"""End-to-end behaviour of the system: the full paper workflow (§III)
executed programmatically, plus optimizer/sharding plumbing sanity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.synthesis import NetworkSpec, create_top_module, synthesize
from repro.core.quantization import (
    default_format,
    fixed_mlp_forward,
    float_mlp_forward,
    output_snr_db,
)
from repro.models import lm
from repro.parallel import sharding as shd


def test_full_workflow_stages(rng):
    """Stage 1 state-space formation → 2 software simulation →
    3 fixed-point analysis → 4/5 synthesis → 6 optimization knob."""
    # 1-2: spec -> network -> simulate
    spec = NetworkSpec(num_inputs=3, num_hidden_layers=4, nodes_per_layer=4, num_outputs=2)
    params, forward = create_top_module(spec)
    u = jnp.asarray(rng.uniform(-1, 1, size=3), jnp.float32)
    y = forward(params, u)
    assert y.shape == (2,)

    # 3: fixed-point analysis picks a word length meeting a 40 dB target
    W = np.asarray(params["W"], np.float64)
    b = np.asarray(params["b"], np.float64)
    beta = np.asarray(params["beta"], np.float64)
    C = np.asarray(params["C"], np.float64)
    U = rng.uniform(-1, 1, size=(64, 3))
    y_ref = float_mlp_forward(W, b, beta, C, U)
    chosen = None
    for bits in (12, 16, 20, 24, 28):
        snr = float(np.mean(output_snr_db(
            y_ref, fixed_mlp_forward(W, b, beta, C, U, default_format(bits)))))
        if snr >= 40.0:
            chosen = bits
            break
    assert chosen is not None and chosen <= 24  # paper: 20-24 bits suffice

    # 4-5: implementation/synthesis report ("RTL" + utilization + timing)
    rep = synthesize(spec, batch=8)
    assert rep.hlo_bytes > 0 and rep.compile_s >= 0

    # 6: optimization — unroll (j) reduces the serial depth estimate
    rep_j = synthesize(dataclasses.replace(spec, unroll=4), batch=8)
    assert rep_j.serial_depth < rep.serial_depth


def test_optimizer_matches_reference_adamw(key):
    """Our AdamW == the textbook update on a toy problem."""
    cfg = optim.AdamWConfig(lr_peak=1e-2, warmup_steps=0, total_steps=10,
                            weight_decay=0.1, clip_norm=0.0)
    params = {"w": jnp.asarray([1.0, -2.0])}
    state = optim.init(params)
    g = {"w": jnp.asarray([0.5, 0.5])}
    new_params, new_state, m = optim.apply(cfg, g, state, params)

    lr = float(optim.lr_schedule(cfg, jnp.int32(1)))
    mhat = (0.1 * 0.5) / (1 - 0.9)
    vhat = (0.05 * 0.25) / (1 - 0.95)
    expect = np.asarray([1.0, -2.0]) - lr * (mhat / (np.sqrt(vhat) + 1e-8)
                                             + 0.1 * np.asarray([1.0, -2.0]))
    np.testing.assert_allclose(new_params["w"], expect, rtol=1e-5)


def test_grad_accumulation_equals_full_batch(key):
    """Microbatched (C-slow-in-time) grads == full-batch grads."""
    cfg = dataclasses.replace(get_smoke_config("smollm-135m"), remat=False)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (8, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    loss_fn = lambda p, b: lm.train_loss(p, cfg, b)

    l1, g1, _ = optim.accumulate_grads(loss_fn, params, batch, 1)
    l4, g4, _ = optim.accumulate_grads(loss_fn, params, batch, 4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, atol=2e-4, rtol=1e-3), g1, g4
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_sharding_specs_cover_all_params(arch):
    """Every parameter gets a spec; remat flag never changes the loss."""
    from jax.sharding import Mesh

    cfg = get_smoke_config(arch)
    params = jax.eval_shape(lambda k: lm.init_params(cfg, k), jax.random.key(0))
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1, 1), ("pod", "data", "model"))
    specs = shd.param_specs(cfg, params, mesh)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(flat_p) == len(flat_s)

    p_real = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    if cfg.family == "encoder":
        batch = {"embeds": jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.frontend_dim)),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)}
    else:
        t = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
        batch = {"tokens": t, "labels": t}
    if cfg.family == "vlm":
        batch["memory"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.frontend_tokens, cfg.frontend_dim))
    l_remat, _ = lm.train_loss(p_real, dataclasses.replace(cfg, remat=True), batch)
    l_plain, _ = lm.train_loss(p_real, dataclasses.replace(cfg, remat=False), batch)
    np.testing.assert_allclose(float(l_remat), float(l_plain), rtol=1e-5)
