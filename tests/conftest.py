import os

# Tests run on the single real CPU device (the dry-run sets its own flags in
# a subprocess).  Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "")

import jax
import numpy as np
import pytest

jax.config.update("jax_default_matmul_precision", "highest")

# Graceful degradation on minimal environments: property-test modules start
# with ``pytest.importorskip("hypothesis")`` so a missing optional dep reports
# as a skip, not a collection error.  Full dev deps: requirements.txt.


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
