"""Attention-variant correctness: MLA absorbed decode, sliding-window ring
buffers, GQA grouping, cross-attention gating."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import attention as att


@pytest.fixture
def mla_cfg():
    return get_smoke_config("deepseek-v2-lite-16b")


def test_mla_absorbed_decode_equals_naive_prefill(mla_cfg, key):
    """The absorbed (latent-space) decode — the MLA serving trick — must
    reproduce the naive expanded attention exactly, token by token."""
    cfg = mla_cfg
    p = att.mla_params(key, cfg)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5

    out_prefill, (c_kv, k_rope) = att.mla_prefill(p, cfg, x)

    cache = {
        "c_kv": jnp.zeros((B, S, cfg.kv_lora_rank)),
        "k_rope": jnp.zeros((B, S, cfg.qk_rope_head_dim)),
    }
    outs = []
    for t in range(S):
        o, cache = att.mla_decode(p, cfg, x[:, t:t + 1], cache, jnp.int32(t))
        outs.append(o)
    out_decode = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(out_decode, out_prefill, atol=1e-4, rtol=1e-3)
    # the latent cache *is* the state: 512+rope floats/token, not 2·H·hd
    np.testing.assert_allclose(cache["c_kv"], c_kv, atol=1e-5)


def test_mla_cache_smaller_than_gqa(mla_cfg):
    cfg = mla_cfg
    mla_per_tok = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    gqa_per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
    assert mla_per_tok < gqa_per_tok / 2


def test_sliding_window_ring_buffer_decode(key):
    """Ring-buffer local attention == full attention with a window mask."""
    cfg = dataclasses.replace(
        get_smoke_config("gemma3-27b"), sliding_window=8, global_every=0,
        tail_pattern=(), n_layers=8,
    )
    from repro.models.transformer import _gqa_decode_local

    p = att.gqa_params(key, cfg)
    B, S, W = 1, 24, cfg.sliding_window
    xs = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.5

    # reference: full-cache decode with window masking
    full_cache = {
        "k": jnp.zeros((B, S, cfg.n_kv_heads, cfg.head_dim)),
        "v": jnp.zeros((B, S, cfg.n_kv_heads, cfg.head_dim)),
    }
    ring_cache = {
        "k": jnp.zeros((B, W, cfg.n_kv_heads, cfg.head_dim)),
        "v": jnp.zeros((B, W, cfg.n_kv_heads, cfg.head_dim)),
    }
    for t in range(S):
        ref, full_cache = att.gqa_decode(p, cfg, xs[:, t:t + 1], full_cache,
                                         jnp.int32(t), window=W)
        got, ring_cache = _gqa_decode_local(p, cfg, xs[:, t:t + 1], ring_cache,
                                            jnp.int32(t))
        np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-3)


def test_gqa_grouping_matches_repeated_kv(key):
    """Grouped einsum == explicit KV-head repetition."""
    B, S, H, KV, hd = 2, 16, 8, 2, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(3), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(4), (B, S, KV, hd))
    mask = att.causal_mask(S, S)
    out = att._sdpa(q, k, v, mask)
    k_rep = jnp.repeat(k, H // KV, axis=2)
    v_rep = jnp.repeat(v, H // KV, axis=2)
    ref = att._sdpa(q, k_rep, v_rep, mask)
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_cross_attention_gate_starts_closed(key):
    """tanh(0)=0 gating: a fresh cross-attn block is an identity residual
    (llama-vision trick so text behaviour is preserved at init)."""
    cfg = get_smoke_config("llama-3.2-vision-90b")
    p = att.cross_attn_params(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model))
    mem = jax.random.normal(key, (2, cfg.frontend_tokens, cfg.frontend_dim))
    out = att.cross_attn(p, cfg, x, mem)
    np.testing.assert_allclose(out, jnp.zeros_like(out), atol=1e-7)


def test_partial_rotary_passthrough(key):
    """phi4-style partial RoPE rotates only the first fraction of channels."""
    from repro.models.layers import apply_rope

    x = jax.random.normal(key, (1, 4, 2, 16))
    pos = jnp.arange(4)[None]
    y = apply_rope(x, pos, 10_000.0, partial=0.5)
    rot = 8
    assert not np.allclose(y[..., :rot], x[..., :rot])
    np.testing.assert_array_equal(y[..., rot:], x[..., rot:])
