"""PR 3 perf-path tests: persistent device-side decode vs the legacy
per-token loop (token-for-token parity), ragged-shape pad/mask in the
generated kernel, the C-slow-batched fused kernel vs the
``cslow_vectorized`` oracle, and the int8 gate MACC vs ``int8_matmul``."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.codegen import (
    CELL_GRAPHS,
    GraphBuilder,
    Schedule,
    Stage,
    bind_cell_params,
    compile_spec,
    pallas_backend,
    xla_backend,
)
from repro.configs import get_smoke_config
from repro.core.synthesis import NetworkSpec
from repro.kernels.int8_matmul.ops import quantized_matmul
from repro.models import lm
from repro.recurrent import cells as rnn_cells
from repro.runtime import DecodeServer, Request


# ---------------------------------------------------------------------------
# persistent decode ≡ legacy per-token loop
# ---------------------------------------------------------------------------

def _requests(vocab: int, n: int = 5, max_new: int = 6, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [
        Request(uid=i,
                prompt=list(rng.integers(1, vocab, size=int(rng.integers(2, 6)))),
                max_new_tokens=max_new)
        for i in range(n)
    ]


def _drain(cfg, params, *, persistent, block_k=8, eos_id=None, reqs=None,
           slots=3, max_seq=48):
    srv = DecodeServer(cfg, params, num_slots=slots, max_seq=max_seq,
                       eos_id=eos_id, block_k=block_k, persistent=persistent)
    for r in reqs or _requests(cfg.vocab):
        srv.submit(r)
    done = srv.run_until_drained()
    return {r.uid: list(r.out_tokens) for r in done}, srv


@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke_config("smollm-135m")
    return cfg, lm.init_params(cfg, jax.random.PRNGKey(0))


@pytest.mark.parametrize("block_k", [1, 4, 8])
def test_persistent_greedy_parity(smollm, block_k):
    """Same seeds → identical greedy tokens, any K (incl. K=1)."""
    cfg, params = smollm
    legacy, _ = _drain(cfg, params, persistent=False)
    persist, _ = _drain(cfg, params, persistent=True, block_k=block_k)
    assert legacy == persist


def test_persistent_eos_and_oom_edges(smollm):
    """EOS mid-block and max-seq exhaustion retire identically."""
    cfg, params = smollm
    legacy, _ = _drain(cfg, params, persistent=False)
    # pick a token the model actually emits mid-stream as the EOS id —
    # deterministic EOS coverage on both paths
    eos = legacy[0][2]
    reqs = lambda: _requests(cfg.vocab, max_new=12)
    l2, _ = _drain(cfg, params, persistent=False, eos_id=eos, reqs=reqs(),
                   max_seq=24)   # small max_seq: some slots hit the oom stop
    p2, _ = _drain(cfg, params, persistent=True, eos_id=eos, reqs=reqs(),
                   max_seq=24)
    assert l2 == p2
    assert any(toks[-1] == eos for toks in l2.values())  # EOS path exercised


def test_persistent_midstream_admit(smollm):
    """Requests admitted while other slots are mid-generation (more requests
    than slots, staggered lengths) still decode token-identically."""
    cfg, params = smollm
    def reqs():
        out = _requests(cfg.vocab, n=7, max_new=5, seed=3)
        for i, r in enumerate(out):   # staggered: slots free up at odd ticks
            r.max_new_tokens = 3 + (i % 4)
        return out
    legacy, _ = _drain(cfg, params, persistent=False, reqs=reqs(), slots=2)
    persist, _ = _drain(cfg, params, persistent=True, block_k=4, reqs=reqs(),
                        slots=2)
    assert legacy == persist


def test_persistent_sync_budget(smollm):
    """The acceptance metric: ≥K tokens per host sync for K-step blocks."""
    cfg, params = smollm
    K = 8
    reqs = _requests(cfg.vocab, n=4, max_new=16, seed=1)
    _, srv = _drain(cfg, params, persistent=True, block_k=K, reqs=reqs,
                    slots=2, max_seq=64)
    stats = srv.stats()
    assert stats["decoded_tokens"] == sum(r.max_new_tokens - 1 for r in reqs)
    assert stats["syncs_per_token"] <= 1.0 / K
    # legacy pays ≥1 sync per tick — strictly more round-trips
    _, srv_l = _drain(cfg, params, persistent=False,
                      reqs=_requests(cfg.vocab, n=4, max_new=16, seed=1),
                      slots=2, max_seq=64)
    assert srv_l.stats()["decode_syncs"] >= 5 * stats["decode_syncs"]


def test_persistent_temperature_terminates(smollm):
    """Sampled (temperature>0) slots decode on device and retire."""
    cfg, params = smollm
    reqs = _requests(cfg.vocab, n=3, max_new=5, seed=2)
    for r in reqs:
        r.temperature = 0.8
    done, srv = _drain(cfg, params, persistent=True, block_k=4, reqs=reqs)
    assert len(done) == 3
    assert all(len(t) == 5 for t in done.values())


def test_persistent_recurrent_arch(smollm):
    """Recurrent (h, c) carries ride the K-step scan — the splice_cache
    layout is the scan carry layout."""
    cfg = get_smoke_config("paper-lstm")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    legacy, _ = _drain(cfg, params, persistent=False,
                       reqs=_requests(cfg.vocab, n=4, max_new=4), slots=2)
    persist, _ = _drain(cfg, params, persistent=True, block_k=4,
                        reqs=_requests(cfg.vocab, n=4, max_new=4), slots=2)
    assert legacy == persist


# ---------------------------------------------------------------------------
# ragged shapes: pad + mask instead of degrade/crash (satellite 1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell,B,T", [("gru", 5, 13), ("lstm", 7, 11),
                                      ("ssm", 3, 17)])
def test_ragged_prime_shapes_match_xla(cell, B, T):
    D, H = 3, 8
    graph = CELL_GRAPHS[cell](D, H)
    stage = Stage(name=cell, graph=graph, schedule=Schedule(steps=T), params={})
    key = jax.random.PRNGKey(0)
    if cell == "ssm":
        from repro.codegen import ssm_params
        cell_p = ssm_params(key, D, H)
    else:
        ctor = rnn_cells.lstm_params if cell == "lstm" else rnn_cells.gru_params
        cell_p = ctor(key, D, H)
    consts = bind_cell_params(cell, cell_p)
    us = jax.random.normal(jax.random.PRNGKey(1), (B, T, D))
    x0 = {n: jnp.zeros((B, w)) for n, w in graph.states.items()}
    # chunk=4, block_b=2: neither divides the prime sizes — forces pad+mask
    fin_p, ys_p = pallas_backend.compile_stage(stage, chunk=4, block_b=2)(
        consts, x0, us)
    fin_x, ys_x = xla_backend.compile_stage(stage)(consts, x0, us)
    assert ys_p.shape == (B, T, graph.node(graph.output).width)
    np.testing.assert_allclose(np.asarray(ys_p), np.asarray(ys_x), atol=1e-5)
    for n in graph.states:
        np.testing.assert_allclose(np.asarray(fin_p[n]), np.asarray(fin_x[n]),
                                   atol=1e-5)


def test_ragged_mlp_per_step_roms():
    """Prime layer count: per-step ROM pages are padded and masked (the
    double-buffered DMA path streams the padded pages)."""
    spec = NetworkSpec(3, 7, 4, 2)
    p1, f1 = compile_spec(spec, backend="xla")
    p2, f2 = compile_spec(spec, backend="pallas")
    u = jax.random.normal(jax.random.PRNGKey(2), (5, 3))
    np.testing.assert_allclose(np.asarray(f1(p1, u)), np.asarray(f2(p2, u)),
                               atol=1e-5)


def test_double_buffer_off_is_equivalent():
    """The BlockSpec fallback (double_buffer=False) matches the DMA path."""
    spec = NetworkSpec(3, 5, 4, 2)
    prog_fwd = {}
    for db in (True, False):
        from repro.codegen import build_program
        prog = build_program(spec)
        fwd = pallas_backend.compile_program(prog, double_buffer=db)
        prog_fwd[db] = np.asarray(fwd(prog.params,
                                      jax.random.normal(jax.random.PRNGKey(3),
                                                        (4, 3))))
    np.testing.assert_allclose(prog_fwd[True], prog_fwd[False], atol=1e-6)


# ---------------------------------------------------------------------------
# C-slow as batching: fused kernel ≡ cslow_vectorized oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", ["gru", "lstm"])
def test_cslow_batched_kernel_matches_vectorized_oracle(cell):
    """`synthesize(backend="pallas")` with c_slow=C runs ONE fused kernel
    over C·B folded streams; the XLA path runs ``cslow_vectorized``'s
    vmap-of-scans.  ≤1e-5 in fp32 interpret mode (acceptance criterion) —
    ragged seq_len so the fold also crosses the pad/mask path."""
    spec = NetworkSpec(3, 2, 8, 2, cell=cell, seq_len=13, c_slow=3)
    px, fx = compile_spec(spec, backend="xla")       # cslow_vectorized oracle
    pp, fp = compile_spec(spec, backend="pallas")    # batch-folded fused kernel
    uc = jax.random.normal(jax.random.PRNGKey(4), (3, 5, 13, 3))
    np.testing.assert_allclose(np.asarray(fp(pp, uc)), np.asarray(fx(px, uc)),
                               atol=1e-5)


def test_fold_streams_roundtrip():
    from repro.core.cslow import fold_streams, unfold_streams

    u = jax.random.normal(jax.random.PRNGKey(0), (3, 4, 7, 2))
    folded = fold_streams(u)
    assert folded.shape == (12, 7, 2)
    np.testing.assert_array_equal(np.asarray(unfold_streams(folded, 3)),
                                  np.asarray(u))


# ---------------------------------------------------------------------------
# int8 gate MACC (paper's fixed-point datapath)
# ---------------------------------------------------------------------------

def test_int8_macc_weight_only_semantics():
    """A one-macc graph on the quantized path computes ``x @ dequant(W)``
    exactly (weight-only int8: per-output-channel scale fused after the
    dot), and pre-packed int8 consts (``prequantize_consts``) reproduce the
    raw-float-const path bit for bit — the contract that lets synthesis
    pack ROM pages once and stream them through the double-buffer DMA."""
    from repro.kernels.int8_matmul.ops import quantize_per_channel

    D, N, B = 6, 8, 4
    g = GraphBuilder()
    u = g.input("u", D)
    g.state("h", N)
    W = g.const("W", (D, N))
    z = g.macc("z", u, W)
    g.update("h", z)
    graph = g.build(output=z)
    stage = Stage(name="mm", graph=graph, schedule=Schedule(steps=1), params={})
    run = pallas_backend.compile_stage(stage, quant_bits=8)
    Wv = jax.random.normal(jax.random.PRNGKey(0), (D, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
    _, ys = run({"W": Wv}, {"h": jnp.zeros((B, N))}, x[:, None, :])
    w_q, s = quantize_per_channel(Wv, axis=-2)
    ref = (x @ w_q.astype(jnp.float32)) * s        # weight-only reference
    np.testing.assert_allclose(np.asarray(ys[:, 0]), np.asarray(ref),
                               atol=1e-6)
    # activations are NOT quantized on this path (the old dynamic-activation
    # datapath is gone): full-precision x flows into the dot
    assert not np.allclose(np.asarray(ref), np.asarray(quantized_matmul(x, Wv)),
                           atol=1e-6)
    packed = pallas_backend.prequantize_consts(graph, {"W": Wv}, 8)
    assert packed["W"].dtype == jnp.int8 and "W.scale" in packed
    _, ys2 = run(packed, {"h": jnp.zeros((B, N))}, x[:, None, :])
    np.testing.assert_array_equal(np.asarray(ys), np.asarray(ys2))


@pytest.mark.parametrize("cell", ["lstm", "gru", "ssm"])
def test_int8_gate_path_within_quant_tolerance(cell):
    """Full cells on the int8 MACC datapath track fp32 within the expected
    8-bit error envelope — and actually differ (the path is live)."""
    spec = NetworkSpec(3, 1, 8, 2, cell=cell, seq_len=12)
    from repro.codegen import build_program
    prog = build_program(spec)
    f_fp = pallas_backend.compile_program(prog)
    f_q8 = pallas_backend.compile_program(prog, quant_bits=8)
    u = jax.random.normal(jax.random.PRNGKey(5), (4, 12, 3))
    a, b = np.asarray(f_fp(prog.params, u)), np.asarray(f_q8(prog.params, u))
    err = np.abs(a - b).max()
    scale = max(np.abs(a).max(), 1e-3)
    assert 0 < err < 0.15 * scale


def test_int8_composes_with_lut_gates():
    """quant_bits<=8 through synthesize: int8 MACC + ROM-LUT activations in
    the same generated kernel (the paper's full fixed-point datapath)."""
    from repro.core.synthesis import synthesize

    spec = NetworkSpec(3, 2, 8, 2, cell="lstm", seq_len=8, quant_bits=8)
    rep = synthesize(spec, batch=2, backend="pallas")
    assert rep.quant["mode"] == "lut" and rep.quant["int8_macc"]
    ssm = NetworkSpec(3, 2, 8, 2, cell="ssm", seq_len=8, quant_bits=8)
    rep2 = synthesize(ssm, batch=2, backend="pallas")
    assert rep2.quant["mode"] == "int8"
    # >8 bits on an af-free cell still has nothing to quantize on pallas
    with pytest.raises(ValueError, match="not supported"):
        synthesize(dataclasses.replace(ssm, quant_bits=16), batch=2,
                   backend="pallas")


def test_block_fast_path_int8_gates():
    """cfg.quant_gate_bits routes the recurrent block's generated-kernel
    prefill through the int8 gate contraction."""
    from repro.configs.paper_lstm import smoke_config

    base = smoke_config()
    cfg = dataclasses.replace(base, use_codegen=True, quant_gate_bits=8)
    params = lm.init_params(base, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, base.vocab)
    ref, _ = lm.prefill(params, base, toks)
    got, _ = lm.prefill(params, cfg, toks)
    err = np.abs(np.asarray(got) - np.asarray(ref)).max()
    assert 0 < err < 0.15 * np.abs(np.asarray(ref)).max()
