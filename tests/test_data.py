"""Data pipeline: determinism, sharding, learnability, straggler hooks."""

import numpy as np
import pytest

from repro.data import DataConfig, TokenPipeline


@pytest.fixture
def pipe():
    return TokenPipeline(DataConfig(vocab=128, seq_len=32, global_batch=8,
                                    num_shards=4, seed=42))


def test_deterministic(pipe):
    a = pipe.batch_at(5, shard=2)
    b = pipe.batch_at(5, shard=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_shards_and_steps_are_distinct(pipe):
    assert not np.array_equal(pipe.batch_at(5, 0)["tokens"], pipe.batch_at(5, 1)["tokens"])
    assert not np.array_equal(pipe.batch_at(5, 0)["tokens"], pipe.batch_at(6, 0)["tokens"])


def test_labels_are_next_tokens(pipe):
    b = pipe.batch_at(0, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_structure(pipe):
    """Every transition respects the fixed successor table (learnable)."""
    b = pipe.batch_at(3, 1)
    toks, labels = b["tokens"], b["labels"]
    ok = np.isin(labels[:, 0], pipe.successors[toks[:, 0]])
    assert ok.all()
    assert 0 < pipe.entropy_floor < np.log(128)


def test_global_batch_shape(pipe):
    gb = pipe.global_batch_at(0)
    assert gb["tokens"].shape == (8, 32)


def test_straggler_reassignment(pipe):
    before = pipe.batch_at(7, shard=3)
    pipe.reassign(3, 1)
    after = pipe.batch_at(7, shard=3)
    expected = pipe.batch_at(7, shard=1)
    assert pipe.effective_shard(3) == 1
    np.testing.assert_array_equal(after["tokens"], expected["tokens"])
    assert not np.array_equal(before["tokens"], after["tokens"])
