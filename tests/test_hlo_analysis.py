"""Unit tests for the trip-count-aware HLO analyzer (the §Roofline
measurement instrument — calibrated here against known-FLOP programs)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops():
    M = 256
    a = jax.ShapeDtypeStruct((M, M), jnp.float32)
    st = analyze(_hlo(lambda a, b: a @ b, a, a))
    assert st.flops == pytest.approx(2 * M**3, rel=1e-6)
    assert st.dot_count == 1


def test_scan_multiplies_by_trip_count():
    M, L = 128, 12
    a = jax.ShapeDtypeStruct((M, M), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, M, M), jnp.float32)

    def scanned(a, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, a, ws)
        return y

    st = analyze(_hlo(scanned, a, ws))
    assert st.flops == pytest.approx(L * 2 * M**3, rel=1e-6)
    assert L in st.while_trips.values()


def test_nested_scans_multiply():
    M, LO, LI = 64, 3, 5
    a = jax.ShapeDtypeStruct((M, M), jnp.float32)
    ws = jax.ShapeDtypeStruct((LO, LI, M, M), jnp.float32)

    def nested(a, ws):
        def outer(x, wg):
            def inner(x, w):
                return x @ w, None
            x, _ = jax.lax.scan(inner, x, wg)
            return x, None
        y, _ = jax.lax.scan(outer, a, ws)
        return y

    st = analyze(_hlo(nested, a, ws))
    assert st.flops == pytest.approx(LO * LI * 2 * M**3, rel=1e-6)


def test_grad_flops_roughly_triple():
    """bwd of a matmul chain costs ~2x the fwd (3x total)."""
    M, L = 128, 8
    a = jax.ShapeDtypeStruct((M, M), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, M, M), jnp.float32)

    def loss(a, ws):
        def body(x, w):
            return x @ w, None
        y, _ = jax.lax.scan(body, a, ws)
        return jnp.sum(y * y)

    fwd = analyze(_hlo(loss, a, ws)).flops
    both = analyze(_hlo(jax.grad(loss, argnums=1), a, ws)).flops
    assert both == pytest.approx(3 * fwd, rel=0.2)


def test_traffic_skips_fusible_elementwise():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    st_mm = analyze(_hlo(lambda a, b: a @ b, x, x))
    # the dot must register traffic (2 reads + 1 write = 12 MB)
    assert st_mm.memory_traffic >= 3 * 1024 * 1024 * 4
