"""Emit a tiny network's Table-I RTL + resource report (paper §IV-D3).

The push-button generator flow on all three backends: the spec is lowered
once to the FSM/datapath IR, then executed through XLA and the generated
fused Pallas kernel (outputs must agree), and finally emitted as the
paper's Create_TopModule → Create_mult Verilog hierarchy.

    python -m examples.codegen_rtl --cell lstm --quant-bits 16
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro.codegen import compile_spec
from repro.core.synthesis import NetworkSpec, synthesize


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="mlp", choices=["mlp", "lstm", "gru", "ssm"])
    ap.add_argument("--quant-bits", type=int, default=None)
    ap.add_argument("--full-rtl", action="store_true", help="print all RTL")
    args = ap.parse_args()

    spec = NetworkSpec(
        num_inputs=3, num_hidden_layers=2, nodes_per_layer=4, num_outputs=2,
        cell=args.cell, seq_len=0 if args.cell == "mlp" else 8,
        quant_bits=args.quant_bits,
    )

    # 1. executable backends agree (the generated kernel's parity check)
    qspec = spec if args.cell == "mlp" \
        else dataclasses.replace(spec, quant_bits=None)  # float-gate parity
    p1, f1 = compile_spec(qspec, backend="xla")
    p2, f2 = compile_spec(qspec, backend="pallas")
    shape = (2, spec.num_inputs) if args.cell == "mlp" \
        else (2, spec.seq_len, spec.num_inputs)
    u = jax.random.normal(jax.random.PRNGKey(0), shape)
    err = float(np.abs(np.asarray(f1(p1, u)) - np.asarray(f2(p2, u))).max())
    print(f"xla vs generated-pallas max |Δ| = {err:.2e}")

    # 2. the RTL's semantics, executed: bit-accurate simulation vs the
    # independent fixed-point golden model (word-for-word equality)
    from repro.codegen import build_program, rtlsim
    from repro.verify import golden

    prog = build_program(spec)
    sim = rtlsim.simulate(prog, np.asarray(u))
    ref = golden.fixed_forward(prog, np.asarray(u))
    exact = bool(np.array_equal(sim.y_codes, ref))
    print(f"rtlsim @ {sim.width}b: bit-exact vs golden model = {exact}, "
          f"fsm cycles = {sim.cycles}, y[0] = {np.round(sim.y[0], 4)}")

    # 3. RTL + resource/latency report
    rep = synthesize(spec, batch=2, backend="verilog")
    print(rep.summary())
    print(rep.resources.summary())
    rtl = rep.rtl
    print(f"--- RTL ({len(rtl.splitlines())} lines) ---")
    if args.full_rtl:
        print(rtl)
    else:
        lines = rtl.splitlines()
        print("\n".join(lines[:40]))
        print(f"... [{len(lines) - 40} more lines; --full-rtl to print]")


if __name__ == "__main__":
    main()
