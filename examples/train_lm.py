"""End-to-end training driver: smollm-135m (the ~100M-class assigned arch)
on the deterministic Markov stream, with checkpoints, straggler monitoring,
and auto-resume.

CPU demo (reduced sequence length, real architecture):
    python -m examples.train_lm --steps 300
Full-size config (for a real pod):
    python -m examples.train_lm --full --steps 300

The loss should fall from ~ln(vocab) toward the stream's entropy floor
(printed) — a real learning signal, not noise.
"""

import argparse
import dataclasses
import json
import os

from repro import optim
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig
from repro.runtime import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="full-size model config (pod-scale; slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="experiments/train_lm_metrics.jsonl")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    if not args.full:
        # keep the real 30-layer / 9-head geometry, CPU-sized width
        cfg = dataclasses.replace(cfg, n_layers=get_config(args.arch).n_layers,
                                  d_model=192, n_heads=3, n_kv_heads=3,
                                  head_dim=64, d_ff=512, vocab=4096)
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=max(args.steps // 5, 10),
        ckpt_dir=args.ckpt_dir, log_every=10, microbatches=args.microbatches,
    )
    ocfg = optim.AdamWConfig(lr_peak=args.lr, warmup_steps=min(50, args.steps // 5),
                             total_steps=args.steps)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, branching=4)

    trainer = Trainer(cfg, tcfg, ocfg, dcfg)
    if not args.resume:
        import shutil

        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
        os.makedirs(args.ckpt_dir, exist_ok=True)
        trainer.ckpt = type(trainer.ckpt)(args.ckpt_dir, keep=tcfg.keep_ckpts)
    res = trainer.run(resume=args.resume)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        for rec in res["metrics"]:
            f.write(json.dumps(rec) + "\n")
    print(f"\nfinal loss {res['final_loss']:.4f} "
          f"(start {res['losses'][0]:.4f}, floor {res['entropy_floor']:.4f})")
    print(f"metrics -> {args.out}; checkpoints -> {args.ckpt_dir}")
    if res["straggler_events"]:
        print("straggler events:", res["straggler_events"])


if __name__ == "__main__":
    main()
