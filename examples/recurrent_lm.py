"""Recurrent-cell quickstart: LSTM/GRU as state-space systems, three views.

  1. cell level  — ``run_cell`` executes an LSTM through the shared
     ``run_scan`` datapath; the same cell C-slows over independent streams.
  2. synthesis   — a recurrent ``NetworkSpec`` through the push-button
     ``synthesize()`` flow (spec → StableHLO "RTL" → report).
  3. serving     — a paper-lstm ModelConfig decoding under continuous
     batching; the per-slot state is just the O(1) (h, c) carry.

    python -m examples.recurrent_lm --cell lstm --requests 6
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.cslow import cslow_vectorized
from repro.core.synthesis import NetworkSpec, synthesize
from repro.models import lm
from repro.recurrent import cells as rnn_cells
from repro.runtime import DecodeServer, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=("lstm", "gru"), default="lstm")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    # --- 1. the cell as a state-space system ---
    key = jax.random.PRNGKey(0)
    T, D, H, C = 32, 16, 24, 4
    ctor = rnn_cells.lstm_params if args.cell == "lstm" else rnn_cells.gru_params
    params = ctor(key, D, H)
    us = jax.random.normal(jax.random.PRNGKey(1), (T, D))
    carry, ys = rnn_cells.run_cell(args.cell, params, us)
    print(f"{args.cell}: one stream   y[{T}] -> last norm "
          f"{float(jnp.linalg.norm(ys[-1])):.3f}")

    model = rnn_cells.make_cell(args.cell, params)
    x0s = rnn_cells.init_carry(args.cell, params, (C,))
    uss = jax.random.normal(jax.random.PRNGKey(2), (C, T, D))
    _, ys_c = cslow_vectorized(model, None, x0s, uss)
    print(f"{args.cell}: C-slow x{C}   outputs {ys_c.shape} (one datapath)")

    # --- 2. push-button synthesis of a recurrent spec ---
    spec = NetworkSpec(num_inputs=D, num_hidden_layers=2, nodes_per_layer=H,
                       num_outputs=4, cell=args.cell, seq_len=T)
    print("synthesize:", synthesize(spec, batch=8).summary())

    # --- 3. continuous-batching decode with (h, c) slot states ---
    cfg = get_smoke_config("paper-lstm")
    if args.cell == "gru":
        import dataclasses

        cfg = dataclasses.replace(cfg, rnn_cell="gru")
    srv = DecodeServer(cfg, lm.init_params(cfg, key), num_slots=3, max_seq=48)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        srv.submit(Request(uid=i, prompt=list(rng.integers(1, cfg.vocab, size=4)),
                           max_new_tokens=8))
    done = srv.run_until_drained()
    toks = sum(len(r.out_tokens) for r in done)
    state_bytes = cfg.kv_cache_bytes(batch=3, seq=48)
    print(f"served {len(done)} requests, {toks} tokens; "
          f"decode state = {state_bytes} bytes total ({args.cell} carries)")


if __name__ == "__main__":
    main()
