"""Long-context SSM demo: the paper's state-space form at work.

Runs a reduced falcon-mamba through a LONG prefill with the chunked
(j-step Φ) scan, then decodes — demonstrating the O(1)-state property that
makes the long_500k cell tractable for SSMs while pure-attention models are
skipped (their KV grows linearly; see DESIGN.md §Arch-applicability).

    python -m examples.longcontext_ssm --seq 8192
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import lm


def state_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=8192)
    ap.add_argument("--decode-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config("falcon-mamba-7b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, args.seq), 0, cfg.vocab)

    t0 = time.perf_counter()
    logits, caches = lm.prefill(params, cfg, toks)
    jax.block_until_ready(logits)
    t1 = time.perf_counter()
    print(f"prefill {args.seq} tokens: {t1 - t0:.2f}s "
          f"({args.seq / (t1 - t0):.0f} tok/s, chunked j-step scan)")

    sb = state_bytes(caches)
    # what a same-geometry attention model would need at this context length
    attn_kv = 2 * args.seq * cfg.n_layers * cfg.d_model * 4
    print(f"SSM state:    {sb / 1e6:.2f} MB (constant in seq_len)")
    print(f"attention KV would be ~{attn_kv / 1e6:.2f} MB at seq={args.seq} "
          f"({attn_kv / sb:.0f}x larger, and growing)")

    cur = int(jnp.argmax(logits[0]))
    pos = args.seq
    out = [cur]
    t2 = time.perf_counter()
    for _ in range(args.decode_tokens - 1):
        lg, caches = lm.decode_step(params, cfg, jnp.asarray([[cur]]), caches,
                                    jnp.int32(pos))
        cur = int(jnp.argmax(lg[0]))
        out.append(cur)
        pos += 1
    t3 = time.perf_counter()
    print(f"decode: {args.decode_tokens} tokens in {t3 - t2:.2f}s "
          f"({(args.decode_tokens) / (t3 - t2):.1f} tok/s) -> {out}")


if __name__ == "__main__":
    main()
