"""Batched serving demo: slot-based continuous batching over the decode
state-space step, with per-request latency stats.

    python -m examples.serve_batched --arch falcon-mamba-7b --requests 12
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.runtime import DecodeServer, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--int8", action="store_true",
                    help="weight-only int8 serving (paper's fixed-point stage)")
    ap.add_argument("--codegen", action="store_true",
                    help="route recurrent prefill through the generated "
                         "fused cell kernel (repro.codegen fast path)")
    ap.add_argument("--persistent", action="store_true",
                    help="persistent device-side decode: one jitted K-step "
                         "loop per dispatch, one host sync per K tokens")
    ap.add_argument("--block-k", type=int, default=8,
                    help="decode steps per persistent block (the serving "
                         "unroll knob)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: consume prompts N tokens/tick, "
                         "interleaved with decode (0 = one-shot prefill)")
    ap.add_argument("--prefix-cache", type=int, default=0, metavar="MB",
                    help="radix prefix cache byte budget in MB (0 = off); "
                         "shared-prefix admissions splice stored state")
    ap.add_argument("--scheduler", choices=["priority", "fifo"],
                    default="priority",
                    help="request scheduler policy (priority classes + "
                         "fairness aging, or plain FIFO)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request TTL: expired requests retire with "
                         "finish_reason='expired:queue'/'expired:decode'")
    ap.add_argument("--watchdog-s", type=float, default=None,
                    help="stall watchdog bound: no serving progress for this "
                         "many seconds aborts in-flight work (error:stalled)")
    ap.add_argument("--shed", action="store_true",
                    help="scheduler load shedding: reject the lowest-"
                         "priority class when deadline math says the queue "
                         "is unserviceable")
    ap.add_argument("--mesh", default=None, metavar="DPxTP",
                    help="shard the server over a device mesh (e.g. 8x1: "
                         "slot pools over the data axis, gate contractions "
                         "over model); needs dp*tp devices, e.g. "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    ap.add_argument("--mesh-layout", choices=["sharded", "folded"],
                    default="sharded",
                    help="'sharded' partitions slots across devices; "
                         "'folded' decodes all shards through one fused "
                         "dispatch (single-host C-slow composition)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.codegen:
        import dataclasses

        cfg = dataclasses.replace(cfg, use_codegen=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if args.int8:
        from repro.runtime.quantized import dequantize_lm_params, quantize_lm_params

        qp, stats = quantize_lm_params(params)
        print(f"int8 weights: {stats['weights_quantized']} tensors, "
              f"{stats['compression']:.2f}x compression "
              f"({stats['bytes_before']/1e6:.1f} -> {stats['bytes_after']/1e6:.1f} MB)")
        params = dequantize_lm_params(qp)  # W8A16: dense compute, int8 storage
    from repro.runtime import SchedulerConfig

    plan = None
    if args.mesh:
        from repro.launch.mesh import make_local_mesh
        from repro.runtime import ShardPlan

        dp, tp = (int(x) for x in args.mesh.lower().split("x"))
        plan = ShardPlan(make_local_mesh(dp=dp, tp=tp),
                         fold_data=args.mesh_layout == "folded")
        print(f"mesh: {plan.describe()}")

    server = DecodeServer(cfg, params, num_slots=args.slots, max_seq=args.max_seq,
                          block_k=args.block_k, persistent=args.persistent,
                          prefill_chunk=args.prefill_chunk,
                          prefix_cache_bytes=args.prefix_cache << 20,
                          scheduler=SchedulerConfig(policy=args.scheduler,
                                                    shed=args.shed),
                          watchdog_s=args.watchdog_s, plan=plan)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(2, 8))
        server.submit(Request(
            uid=i,
            prompt=list(rng.integers(1, cfg.vocab, size=plen)),
            max_new_tokens=args.max_new,
            deadline_s=args.deadline_s,
        ))
    done = server.run_until_drained()
    wall = time.perf_counter() - t0

    toks = sum(len(r.out_tokens) for r in done)
    served = [r for r in done if r.first_token_at is not None]  # admission may reject
    ttfts = [r.first_token_at - r.submitted_at for r in served]
    lats = [r.done_at - r.submitted_at for r in served]
    stats = server.stats()
    mode = f"persistent(K={args.block_k})" if args.persistent else "per-token"
    print(f"arch={cfg.name} slots={args.slots} requests={len(done)} mode={mode}")
    print(f"generated {toks} tokens in {wall:.2f}s -> {toks / wall:.1f} tok/s "
          f"({stats['syncs_per_token']:.3f} host syncs/token)")
    if args.prefill_chunk:
        pf = stats["prefill"]
        print(f"prefill chunk={args.prefill_chunk}: {pf['chunks_run']} chunks, "
              f"max {pf['max_prompt_steps_per_tick']} prompt steps/tick")
    if args.prefix_cache:
        pc = stats["prefix_cache"]
        print(f"prefix cache: {pc['hits']} hits / {pc['partial_hits']} partial "
              f"/ {pc['misses']} misses, {pc['prompt_steps_saved']} prompt "
              f"steps saved, {pc['bytes_in_use'] / 1e6:.1f} MB")
    if served:
        print(f"TTFT   p50={np.percentile(ttfts, 50)*1e3:.0f}ms p95={np.percentile(ttfts, 95)*1e3:.0f}ms")
        print(f"E2E    p50={np.percentile(lats, 50)*1e3:.0f}ms p95={np.percentile(lats, 95)*1e3:.0f}ms")
    if plan is not None:
        mesh_stats = stats["mesh"]
        print(f"mesh dp={mesh_stats['dp']} tp={mesh_stats['tp']} "
              f"layout={mesh_stats['layout']}: tokens/shard="
              f"{mesh_stats['decoded_tokens_by_shard']}")
    health = stats["health"]
    print(f"health: {health['status']} (quarantined={health['quarantined_slots']}, "
          f"stalled_events={health['stalled_events']})")
    reasons = {}
    for r in done:
        reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
    if set(reasons) - {"eos", "max_tokens"}:
        print(f"finish reasons: {reasons}")
    for r in done[:3]:
        print(f"  req{r.uid}: prompt={r.prompt} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
