"""Quickstart: the paper's six-stage workflow (§III) on its own case study.

    python -m examples.quickstart          (PYTHONPATH=src, from repo root)

Stage 1  state-space formation   — NetworkSpec -> eq. (8) program
Stage 2  software simulation     — float64 reference run
Stage 3  fixed-point analysis    — pick the word length for a 40 dB target
Stage 4  architecture/implement  — jit + lower (StableHLO = the "RTL")
Stage 5  verification            — fixed-point vs double-precision SNR
Stage 6  optimization            — the j/unroll resource-speed knob
"""

import dataclasses

import numpy as np

from repro.configs.paper_mlp import CASE_STUDY
from repro.core.quantization import (
    default_format,
    fixed_mlp_forward,
    float_mlp_forward,
    output_snr_db,
)
from repro.core.synthesis import create_top_module, synthesize


def main() -> None:
    print("== Stage 1: state-space formation (paper eq. 8) ==")
    spec = CASE_STUDY
    params, forward = create_top_module(spec)
    print(f"   network: {spec.name} (3 inputs, 4x4 hidden, 2 outputs, tanh)")

    print("== Stage 2: software simulation (float64 reference) ==")
    rng = np.random.default_rng(0)
    U = rng.uniform(-1, 1, size=(256, spec.num_inputs))
    W = np.asarray(params["W"], np.float64)
    b = np.asarray(params["b"], np.float64)
    beta = np.asarray(params["beta"], np.float64)
    C = np.asarray(params["C"], np.float64)
    y_ref = float_mlp_forward(W, b, beta, C, U)
    print(f"   y_ref[0] = {np.round(y_ref[0], 4)}")

    print("== Stage 3: fixed-point analysis (target: 40 dB) ==")
    chosen = None
    for bits in (8, 12, 16, 20, 24):
        y = fixed_mlp_forward(W, b, beta, C, U, default_format(bits))
        snr = float(np.mean(output_snr_db(y_ref, y)))
        mark = ""
        if chosen is None and snr >= 40:
            chosen = bits
            mark = "   <-- selected"
        print(f"   {bits:2d} bits -> {snr:7.2f} dB{mark}")
    print(f"   word length: {chosen} bits (paper: 20-24 acceptable)")

    print("== Stage 4/5: synthesis ('RTL' = StableHLO) + verification ==")
    rep = synthesize(spec, batch=64)
    print(f"   {rep.summary()}")

    print("== Stage 6: optimization (j-step unroll knob) ==")
    rep_j = synthesize(dataclasses.replace(spec, unroll=4), batch=64)
    print(f"   unroll=4: serial depth {rep.serial_depth} -> {rep_j.serial_depth}")
    print("done.")


if __name__ == "__main__":
    main()
